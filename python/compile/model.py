"""L2 — JAX branch programs for Parallax's CPU-fallback execution.

The Rust coordinator (L3) never runs Python: at build time every program
in :data:`REGISTRY` is lowered by :mod:`compile.aot` to HLO text under
``artifacts/`` plus a ``manifest.json`` describing its signature.  At
runtime the Rust engine maps each scheduled fallback branch onto one of
these programs (the zoo's shape universe is chosen to line up).

Each program composes L1 Pallas kernels — so the HLO the Rust client
compiles contains the kernels' tiled schedules, not a re-derived XLA
lowering.  Weights are *inputs*: Parallax does not modify or own model
weights (the paper's non-invasiveness property), so the programs are
pure functions of (activations, weights).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import conv as conv_k
from .kernels import elementwise as ew_k
from .kernels import matmul as mm_k
from .kernels import norm as norm_k
from .kernels import ref


F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Program:
    """One AOT-compilable branch program.

    name: stable identifier used by the Rust executable cache.
    fn: jax function (positional array args) returning a tuple.
    arg_shapes: shapes of the example arguments used for lowering.
    flops: analytic MAC*2 count — lets the Rust side sanity-check the
        FLOP estimator against the artifact it is about to run.
    ref_fn: pure-jnp oracle with the same signature (for pytest).
    """

    name: str
    fn: Callable
    arg_shapes: Sequence[Sequence[int]]
    flops: int
    ref_fn: Callable | None = None

    def example_args(self):
        return [jax.ShapeDtypeStruct(tuple(s), F32) for s in self.arg_shapes]


# ---------------------------------------------------------------------------
# program constructors


def make_matmul(m: int, k: int, n: int) -> Program:
    def fn(x, y):
        return (mm_k.matmul(x, y),)

    def rfn(x, y):
        return (ref.matmul(x, y),)

    return Program(
        name=f"matmul_{m}x{k}x{n}",
        fn=fn,
        arg_shapes=[(m, k), (k, n)],
        flops=2 * m * k * n,
        ref_fn=rfn,
    )


def make_linear(m: int, k: int, n: int, act: str) -> Program:
    """Fused FullyConnected: x@w + b with activation epilogue."""

    def fn(x, w, b):
        return (mm_k.matmul_bias_act(x, w, b, act=act),)

    def rfn(x, w, b):
        return (ref.bias_act(ref.matmul(x, w), b, act),)

    return Program(
        name=f"linear_{act}_{m}x{k}x{n}",
        fn=fn,
        arg_shapes=[(m, k), (k, n), (n,)],
        flops=2 * m * k * n + 3 * m * n,
        ref_fn=rfn,
    )


def make_ffn(t: int, d: int, h: int) -> Program:
    """Transformer FFN block: LN -> gelu linear -> linear -> residual."""

    def fn(x, g, b, w1, b1, w2, b2):
        y = norm_k.layernorm(x, g, b)
        y = mm_k.matmul_bias_act(y, w1, b1, act="gelu")
        y = mm_k.matmul_bias_act(y, w2, b2, act="none")
        return (ew_k.binary(x, y, op="add"),)

    def rfn(x, g, b, w1, b1, w2, b2):
        y = ref.layernorm(x, g, b)
        return (x + ref.ffn(y, w1, b1, w2, b2),)

    return Program(
        name=f"ffn_{t}x{d}x{h}",
        fn=fn,
        arg_shapes=[(t, d), (d,), (d,), (d, h), (h,), (h, d), (d,)],
        flops=4 * t * d * h + 10 * t * d,
        ref_fn=rfn,
    )


def make_attn(t: int, d: int, heads: int) -> Program:
    """Pre-LN multi-head self-attention block with residual."""

    def fn(x, g, b, wq, wk, wv, wo):
        y = norm_k.layernorm(x, g, b)
        y = attn_k.mha(y, wq, wk, wv, wo, num_heads=heads)
        return (ew_k.binary(x, y, op="add"),)

    def rfn(x, g, b, wq, wk, wv, wo):
        y = ref.layernorm(x, g, b)
        return (x + ref.mha(y, wq, wk, wv, wo, heads),)

    return Program(
        name=f"attn_{t}x{d}_h{heads}",
        fn=fn,
        arg_shapes=[(t, d), (d,), (d,)] + [(d, d)] * 4,
        flops=8 * t * d * d + 4 * t * t * d,
        ref_fn=rfn,
    )


def make_conv_block(h: int, w: int, cin: int, cout: int, stride: int = 1,
                    act: str = "silu") -> Program:
    """Conv3x3 + activation — the YOLO-style CPU fallback unit."""

    def fn(x, wt):
        y = conv_k.conv2d(x, wt, stride=stride)
        return (ew_k.unary(y, op=act),)

    def rfn(x, wt):
        y = ref.conv2d(x, wt, stride=stride)
        return (ref.silu(y) if act == "silu" else ref.relu(y),)

    ho, wo = -(-h // stride), -(-w // stride)
    return Program(
        name=f"conv3x3_{act}_{h}x{w}x{cin}x{cout}_s{stride}",
        fn=fn,
        arg_shapes=[(1, h, w, cin), (3, 3, cin, cout)],
        flops=2 * 9 * cin * cout * ho * wo + 4 * ho * wo * cout,
        ref_fn=rfn,
    )


def make_dwconv_block(h: int, w: int, c: int, stride: int = 1) -> Program:
    """Depthwise 3x3 + pointwise 1x1 (mobile inverted-bottleneck slice)."""

    def fn(x, wd, wp):
        y = conv_k.dwconv2d(x, wd, stride=stride)
        y = ew_k.unary(y, op="relu")
        return (conv_k.conv2d(y, wp),)

    def rfn(x, wd, wp):
        y = ref.relu(ref.dwconv2d(x, wd, stride=stride))
        return (ref.conv2d(y, wp),)

    ho, wo = -(-h // stride), -(-w // stride)
    return Program(
        name=f"dwsep_{h}x{w}x{c}_s{stride}",
        fn=fn,
        arg_shapes=[(1, h, w, c), (3, 3, c, 1), (1, 1, c, c)],
        flops=2 * 9 * c * ho * wo + 2 * c * c * ho * wo + ho * wo * c,
        ref_fn=rfn,
    )


def make_layernorm(t: int, d: int) -> Program:
    def fn(x, g, b):
        return (norm_k.layernorm(x, g, b),)

    def rfn(x, g, b):
        return (ref.layernorm(x, g, b),)

    return Program(
        name=f"layernorm_{t}x{d}",
        fn=fn,
        arg_shapes=[(t, d), (d,), (d,)],
        flops=8 * t * d,
        ref_fn=rfn,
    )


def make_softmax(t: int, d: int) -> Program:
    def fn(x):
        return (norm_k.softmax(x),)

    def rfn(x):
        return (ref.softmax(x),)

    return Program(
        name=f"softmax_{t}x{d}",
        fn=fn,
        arg_shapes=[(t, d)],
        flops=5 * t * d,
        ref_fn=rfn,
    )


def make_binary(n: int, op: str) -> Program:
    def fn(x, y):
        return (ew_k.binary(x, y, op=op),)

    def rfn(x, y):
        return (ref.elementwise(x, y, op),)

    return Program(
        name=f"ew_{op}_{n}",
        fn=fn,
        arg_shapes=[(n,), (n,)],
        flops=n,
        ref_fn=rfn,
    )


def make_unary(n: int, op: str) -> Program:
    def fn(x):
        return (ew_k.unary(x, op=op),)

    def rfn(x):
        return ((ref.relu(x) if op == "relu" else ref.silu(x)),)

    return Program(
        name=f"ew_{op}_{n}",
        fn=fn,
        arg_shapes=[(n,)],
        flops=4 * n,
        ref_fn=rfn,
    )


# ---------------------------------------------------------------------------
# the shape universe
#
# Shapes line up with the model zoo in rust/src/models/:
#   CLIP text encoder : T=77,  D=512,  H=2048, 8 heads
#   DistilBERT        : T=128, D=768,  H=3072, 12 heads
#   Whisper-Tiny enc  : T=192 (pooled slice, padded), D=384, H=1536, 6 heads
#   SwinV2-Tiny       : windows of 64 tokens, D=96..192
#   YOLOv8n           : conv ladders at 40/20 spatial, C=64..256

REGISTRY: dict[str, Program] = {}


def _add(p: Program) -> None:
    assert p.name not in REGISTRY, f"duplicate program {p.name}"
    REGISTRY[p.name] = p


def _build_registry() -> None:
    # generic GEMMs (router fallback for odd branches)
    for m, k, n in [(64, 64, 64), (128, 128, 128), (256, 256, 256)]:
        _add(make_matmul(m, k, n))

    # CLIP text encoder blocks
    _add(make_attn(77, 512, 8))
    _add(make_ffn(77, 512, 2048))
    _add(make_layernorm(77, 512))
    _add(make_linear(77, 512, 512, "none"))

    # DistilBERT blocks
    _add(make_attn(128, 768, 12))
    _add(make_ffn(128, 768, 3072))
    _add(make_layernorm(128, 768))

    # Whisper-Tiny encoder blocks (T=192 padded)
    _add(make_attn(192, 384, 6))
    _add(make_ffn(192, 384, 1536))
    _add(make_layernorm(192, 384))
    _add(make_softmax(192, 384))

    # Swin windows (64-token windows)
    _add(make_attn(64, 96, 3))
    _add(make_attn(64, 192, 6))
    _add(make_ffn(64, 96, 384))
    _add(make_ffn(64, 192, 768))

    # YOLO conv ladder (batch 1, NHWC)
    _add(make_conv_block(40, 40, 64, 64))
    _add(make_conv_block(40, 40, 64, 128, stride=2))
    _add(make_conv_block(20, 20, 128, 128))
    _add(make_conv_block(20, 20, 128, 256, stride=2))
    _add(make_dwconv_block(40, 40, 64))
    _add(make_dwconv_block(20, 20, 128))

    # glue
    for n in [4096, 65536]:
        _add(make_binary(n, "add"))
        _add(make_unary(n, "relu"))
        _add(make_unary(n, "silu"))


_build_registry()
