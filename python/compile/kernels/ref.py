"""Pure-jnp reference oracles for every Pallas kernel.

These are the CORE correctness signal: each kernel in this package must
match its oracle to float32 tolerance across the shape sweeps in
``python/tests``.  The oracles are intentionally written in the most
obvious jnp form — no tiling, no tricks — so that a disagreement always
indicts the kernel, not the reference.
"""

import jax
import jax.numpy as jnp


def matmul(x, y):
    """Plain dense matmul: (M,K) @ (K,N) -> (M,N)."""
    return jnp.matmul(x, y)


def bias_act(x, b, act):
    """x + b followed by an activation from {none, relu, gelu, silu}."""
    y = x + b
    if act == "relu":
        return jax.nn.relu(y)
    if act == "gelu":
        return jax.nn.gelu(y)
    if act == "silu":
        return jax.nn.silu(y)
    return y


def elementwise(x, y, op):
    """Binary elementwise op from {add, sub, mul, max}."""
    if op == "add":
        return x + y
    if op == "sub":
        return x - y
    if op == "mul":
        return x * y
    if op == "max":
        return jnp.maximum(x, y)
    raise ValueError(op)


def relu(x):
    return jax.nn.relu(x)


def silu(x):
    return jax.nn.silu(x)


def softmax(x):
    """Numerically stable softmax over the last axis."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attention(q, k, v):
    """Single-head scaled dot-product attention.

    q: (T, d), k: (S, d), v: (S, d) -> (T, d)
    """
    d = q.shape[-1]
    scores = jnp.matmul(q, k.T) / jnp.sqrt(jnp.float32(d))
    return jnp.matmul(softmax(scores), v)


def mha(x, wq, wk, wv, wo, num_heads):
    """Multi-head self-attention block over x: (T, D)."""
    t, dmodel = x.shape
    dh = dmodel // num_heads
    q = jnp.matmul(x, wq).reshape(t, num_heads, dh).transpose(1, 0, 2)
    k = jnp.matmul(x, wk).reshape(t, num_heads, dh).transpose(1, 0, 2)
    v = jnp.matmul(x, wv).reshape(t, num_heads, dh).transpose(1, 0, 2)
    out = jax.vmap(attention)(q, k, v)  # (H, T, dh)
    out = out.transpose(1, 0, 2).reshape(t, dmodel)
    return jnp.matmul(out, wo)


def ffn(x, w1, b1, w2, b2):
    """Transformer FFN: gelu(x@w1+b1)@w2+b2."""
    h = jax.nn.gelu(jnp.matmul(x, w1) + b1)
    return jnp.matmul(h, w2) + b2


def conv2d(x, w, stride=1, padding="SAME"):
    """NHWC conv with HWIO weights."""
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def dwconv2d(x, w, stride=1, padding="SAME"):
    """Depthwise NHWC conv; w: (Kh, Kw, C, 1) with channel multiplier 1."""
    c = x.shape[-1]
    kh, kw, _, _ = w.shape
    # HWIO with feature_group_count=C expects rhs (Kh, Kw, 1, C).
    w = w.reshape(kh, kw, c, 1).transpose(0, 1, 3, 2)
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def avgpool2d(x, k=2, stride=2):
    """NHWC average pooling."""
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, k, k, 1), (1, stride, stride, 1), "VALID"
    ) / (k * k)


def maxpool2d(x, k=2, stride=2):
    """NHWC max pooling."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), "VALID"
    )


def im2col(x, kh, kw, stride=1, padding="SAME"):
    """Unfold NHWC x into (N, Ho, Wo, Kh*Kw*C) patches — reference for the
    conv2d kernel's internal layout."""
    n, h, w, c = x.shape
    if padding == "SAME":
        # XLA SAME convention: output = ceil(in / stride), pad split
        # low-first so the high side absorbs the remainder.
        def same_pad(dim, k):
            out = -(-dim // stride)
            total = max((out - 1) * stride + k - dim, 0)
            return total // 2, total - total // 2

        (ph_lo, ph_hi), (pw_lo, pw_hi) = same_pad(h, kh), same_pad(w, kw)
        x = jnp.pad(x, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
    ho = (x.shape[1] - kh) // stride + 1
    wo = (x.shape[2] - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[:, i : i + ho * stride : stride, j : j + wo * stride : stride, :])
    return jnp.concatenate(cols, axis=-1).reshape(n, ho, wo, kh * kw * c)
