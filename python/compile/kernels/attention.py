"""Fused scaled-dot-product attention Pallas kernel.

One grid step owns a block of query rows and the full K/V (sequence
lengths in the paper's text-encoder branches are ≤ 1500, so K/V fit in a
VMEM-sized tile).  QKᵀ → stable softmax → ·V happens in one kernel, so
the (T,S) score matrix never round-trips to HBM — the same insight flash
attention applies on GPUs, re-expressed as a Pallas BlockSpec schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[...]                      # (bq, d)
    k = k_ref[...]                      # (S, d)
    v = v_ref[...]                      # (S, d)
    s = jnp.dot(q, k.T, preferred_element_type=q.dtype) * scale
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=q.dtype)


@functools.partial(jax.jit, static_argnames=("bq",))
def attention(q, k, v, *, bq: int = 128):
    """Single-head attention: q (T,d), k (S,d), v (S,d) -> (T,d)."""
    t, d = q.shape
    s, d2 = k.shape
    assert d == d2 and v.shape == (s, d)
    b = _block(t, bq)
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale),
        grid=(t // b,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (i, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), q.dtype),
        interpret=True,
    )(q, k, v)


def mha(x, wq, wk, wv, wo, *, num_heads: int):
    """Multi-head self-attention over x (T, D) using the fused kernel
    per head (vmap over the head axis) and pallas matmuls for the
    projections."""
    from . import matmul as mm

    t, dmodel = x.shape
    dh = dmodel // num_heads
    q = mm.matmul(x, wq).reshape(t, num_heads, dh).transpose(1, 0, 2)
    k = mm.matmul(x, wk).reshape(t, num_heads, dh).transpose(1, 0, 2)
    v = mm.matmul(x, wv).reshape(t, num_heads, dh).transpose(1, 0, 2)
    out = jax.vmap(attention)(q, k, v)
    out = out.transpose(1, 0, 2).reshape(t, dmodel)
    return mm.matmul(out, wo)
