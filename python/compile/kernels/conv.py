"""Convolution and pooling Pallas kernels.

Conv2D is lowered as im2col + the tiled Pallas matmul — the standard
mobile-CPU strategy (TFLite's XNNPACK does the same), and on TPU the
resulting GEMM is exactly the MXU-friendly shape.  Depthwise conv and
pooling run as spatial Pallas kernels with the tap loop unrolled inside
one grid step (K is 3 or 5 for every model in the zoo).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mm
from . import ref as _ref


def _block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def conv2d(x, w, *, stride: int = 1, padding: str = "SAME"):
    """NHWC conv via im2col + Pallas tiled matmul.

    x: (N, H, W, Cin); w: (Kh, Kw, Cin, Cout) -> (N, Ho, Wo, Cout).
    """
    kh, kw, cin, cout = w.shape
    cols = _ref.im2col(x, kh, kw, stride=stride, padding=padding)
    n, ho, wo, patch = cols.shape
    flat = cols.reshape(n * ho * wo, patch)
    wm = w.reshape(patch, cout)
    out = mm.matmul(flat, wm)
    return out.reshape(n, ho, wo, cout)


def _dwconv_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, stride: int):
    """One batch image per grid step; taps unrolled (kh*kw static)."""
    x = x_ref[...][0]                   # (Hp, Wp, C) padded input
    w = w_ref[...]                      # (Kh, Kw, C)
    _, ho, wo, _ = o_ref.shape
    acc = jnp.zeros(o_ref.shape[1:], o_ref.dtype)
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                x, (i, j, 0),
                (i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, x.shape[2]),
                (stride, stride, 1),
            )
            acc = acc + patch * w[i, j, :]
    o_ref[...] = acc[None]


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def dwconv2d(x, w, *, stride: int = 1, padding: str = "SAME"):
    """Depthwise NHWC conv; w: (Kh, Kw, C, 1) like the jax reference."""
    kh, kw, c, mult = w.shape
    assert mult == 1, "channel multiplier 1 only"
    n, h, wid, c2 = x.shape
    assert c == c2
    if padding == "SAME":
        # Match XLA SAME semantics (see ref.im2col): low side gets the
        # smaller half of the total pad.
        def same_pad(dim, k):
            out = -(-dim // stride)
            total = max((out - 1) * stride + k - dim, 0)
            return total // 2, total - total // 2

        (ph_lo, ph_hi), (pw_lo, pw_hi) = same_pad(h, kh), same_pad(wid, kw)
        xp = jnp.pad(x, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0)))
    else:
        xp = x
    hp, wp = xp.shape[1], xp.shape[2]
    ho = (hp - kh) // stride + 1
    wo = (wp - kw) // stride + 1
    return pl.pallas_call(
        functools.partial(_dwconv_kernel, kh=kh, kw=kw, stride=stride),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, hp, wp, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, c), x.dtype),
        interpret=True,
    )(xp, w.reshape(kh, kw, c))


def _pool_kernel(x_ref, o_ref, *, k: int, stride: int, mode: str):
    x = x_ref[...]
    ho, wo = o_ref.shape[1], o_ref.shape[2]
    init = -jnp.inf if mode == "max" else 0.0
    acc = jnp.full(o_ref.shape, init, o_ref.dtype)
    for i in range(k):
        for j in range(k):
            patch = jax.lax.slice(
                x, (0, i, j, 0),
                (1, i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, x.shape[3]),
                (1, stride, stride, 1),
            )
            acc = jnp.maximum(acc, patch) if mode == "max" else acc + patch
    o_ref[...] = acc if mode == "max" else acc / (k * k)


def _pool(x, k, stride, mode):
    n, h, w, c = x.shape
    ho = (h - k) // stride + 1
    wo = (w - k) // stride + 1
    return pl.pallas_call(
        functools.partial(_pool_kernel, k=k, stride=stride, mode=mode),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, ho, wo, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, c), x.dtype),
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=("k", "stride"))
def maxpool2d(x, *, k: int = 2, stride: int = 2):
    """NHWC max pooling (VALID)."""
    return _pool(x, k, stride, "max")


@functools.partial(jax.jit, static_argnames=("k", "stride"))
def avgpool2d(x, *, k: int = 2, stride: int = 2):
    """NHWC average pooling (VALID)."""
    return _pool(x, k, stride, "avg")
