"""Tiled Pallas matmul — the L1 flagship kernel.

The paper's CPU-fallback branches are dominated by dense GEMMs
(FullyConnected / MatMul in Appendix A).  This kernel expresses the
HBM↔VMEM schedule with a BlockSpec grid:

  grid = (M/bm, N/bn, K/bk)

Each (i, j) output tile is accumulated over the k axis of the grid; the
k==0 step zero-initialises the accumulator.  Block shapes default to
128×128×128 — one MXU-shaped tile per step — and are clamped to the
problem size so small shapes still work.  ``interpret=True`` is mandatory
on the CPU PJRT plugin (real-TPU lowering emits Mosaic custom-calls the
CPU client cannot run); the BlockSpec structure is what we cost-model in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One (bm, bn) output tile; accumulate over the k grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _block(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= want (keeps grids exact)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """Pallas tiled matmul: (M,K) @ (K,N) -> (M,N) in f32."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {y.shape}"
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk"))
def matmul_bias_act(x, y, b, *, act: str = "none",
                    bm: int = 128, bn: int = 128, bk: int = 128):
    """Fused (M,K)@(K,N) + bias(N) + activation — one VMEM round-trip.

    The epilogue runs on the last k step so the bias/activation never
    touches HBM-resident partial sums.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2 and b.shape == (n,)
    bm_, bn_, bk_ = _block(m, bm), _block(n, bn), _block(k, bk)
    n_k = k // bk_

    def kernel(x_ref, y_ref, b_ref, o_ref):
        kk = pl.program_id(2)

        @pl.when(kk == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
        )

        @pl.when(kk == n_k - 1)
        def _epilogue():
            acc = o_ref[...] + b_ref[...]
            if act == "relu":
                acc = jax.nn.relu(acc)
            elif act == "gelu":
                acc = jax.nn.gelu(acc)
            elif act == "silu":
                acc = jax.nn.silu(acc)
            o_ref[...] = acc

    return pl.pallas_call(
        kernel,
        grid=(m // bm_, n // bn_, n_k),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn_,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y, b)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint of one grid step: x-tile + y-tile + o-tile.

    Used by the §Perf block-shape sweep to check the schedule fits the
    ~16 MiB per-core VMEM of a TPU and to estimate MXU utilisation.
    """
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(bm: int, bn: int, bk: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes a (bm,bn,bk) tile keeps busy (structure-level
    estimate: dims not multiple of the systolic array waste lanes)."""
    eff = lambda d: d / (((d + mxu - 1) // mxu) * mxu)
    return eff(bm) * eff(bn) * eff(bk)
