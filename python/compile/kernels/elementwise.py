"""Blocked elementwise Pallas kernels.

These cover the glue ops (Add/Mul/Sub/Max, ReLU/SiLU) that appear between
the compute-heavy ops inside a fallback branch.  They are deliberately
flattened-1D: the rust engine treats elementwise ops as shape-agnostic
and calls the artifact whose element count matches.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


def _binary_kernel(op):
    def kernel(x_ref, y_ref, o_ref):
        x, y = x_ref[...], y_ref[...]
        if op == "add":
            o_ref[...] = x + y
        elif op == "sub":
            o_ref[...] = x - y
        elif op == "mul":
            o_ref[...] = x * y
        elif op == "max":
            o_ref[...] = jnp.maximum(x, y)
        else:
            raise ValueError(op)
    return kernel


@functools.partial(jax.jit, static_argnames=("op", "bs"))
def binary(x, y, *, op: str = "add", bs: int = 4096):
    """Binary elementwise over same-shape operands (any rank)."""
    shape = x.shape
    xf, yf = x.reshape(-1), y.reshape(-1)
    n = xf.shape[0]
    b = _block(n, bs)
    out = pl.pallas_call(
        _binary_kernel(op),
        grid=(n // b,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,)),
                  pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(xf, yf)
    return out.reshape(shape)


def _unary_kernel(op):
    def kernel(x_ref, o_ref):
        x = x_ref[...]
        if op == "relu":
            o_ref[...] = jax.nn.relu(x)
        elif op == "silu":
            o_ref[...] = jax.nn.silu(x)
        elif op == "gelu":
            o_ref[...] = jax.nn.gelu(x)
        else:
            raise ValueError(op)
    return kernel


@functools.partial(jax.jit, static_argnames=("op", "bs"))
def unary(x, *, op: str = "relu", bs: int = 4096):
    """Unary activation over any-rank input."""
    shape = x.shape
    xf = x.reshape(-1)
    n = xf.shape[0]
    b = _block(n, bs)
    out = pl.pallas_call(
        _unary_kernel(op),
        grid=(n // b,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(xf)
    return out.reshape(shape)
