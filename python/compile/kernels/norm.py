"""Row-tiled normalisation Pallas kernels: LayerNorm and softmax.

Both operate over the last axis of a (rows, D) input; the grid walks row
blocks so each step reduces entirely inside VMEM (one pass for softmax's
max/sum thanks to per-block full-row residency — D for the paper's models
is ≤ 4096 floats, far under VMEM limits).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("eps", "br"))
def layernorm(x, gamma, beta, *, eps: float = 1e-5, br: int = 128):
    """LayerNorm over the last axis of a (rows, D) tensor."""
    rows, d = x.shape
    b = _block(rows, br)
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(rows // b,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x, gamma, beta)


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("br",))
def softmax(x, *, br: int = 128):
    """Numerically-stable softmax over the last axis of (rows, D)."""
    rows, d = x.shape
    b = _block(rows, br)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(rows // b,),
        in_specs=[pl.BlockSpec((b, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x)
