"""L1 — Pallas kernels for Parallax's CPU-fallback branch programs.

Every kernel is checked against the pure-jnp oracle in :mod:`.ref` by
``python/tests``.  All kernels run with ``interpret=True`` (CPU PJRT
cannot execute Mosaic custom-calls); the BlockSpec structure is still
the real TPU schedule and is what EXPERIMENTS.md §Perf cost-models.
"""

from . import attention, conv, elementwise, matmul, norm, ref  # noqa: F401
