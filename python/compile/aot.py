"""AOT lowering: JAX branch programs → HLO text + manifest.

Interchange format is HLO **text**, not ``.serialize()``: the published
``xla`` crate links xla_extension 0.5.1, which rejects jax≥0.5's
HloModuleProto (64-bit instruction ids fail its ``id() <= INT_MAX``
check).  ``HloModuleProto::from_text_file`` re-parses and reassigns ids,
so text round-trips cleanly (see /opt/xla-example/README.md).

Outputs, under ``--out-dir`` (default ``artifacts/``):

  <name>.hlo.txt     one file per program in compile.model.REGISTRY
  manifest.json      [{name, file, inputs: [[dims], ...], outputs, flops}]

Incremental: a program is re-lowered only when its HLO file is missing
or older than the compile/ sources, so ``make artifacts`` is a cheap
no-op on an unchanged tree.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_program(prog: model.Program) -> str:
    lowered = jax.jit(prog.fn).lower(*prog.example_args())
    return to_hlo_text(lowered)


def output_shapes(prog: model.Program) -> list[list[int]]:
    out = jax.eval_shape(prog.fn, *prog.example_args())
    return [list(o.shape) for o in out]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None,
                    help="artifact directory (default: <repo>/artifacts)")
    ap.add_argument("--only", default=None,
                    help="comma-separated program names to (re)lower")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if artifacts are up to date")
    # kept for Makefile compatibility: --out FILE lowers a single legacy
    # model.hlo.txt containing the first registry program.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    repo = pathlib.Path(__file__).resolve().parents[2]
    out_dir = pathlib.Path(args.out_dir) if args.out_dir else repo / "artifacts"
    out_dir.mkdir(parents=True, exist_ok=True)

    src_mtime = max(
        p.stat().st_mtime
        for p in (repo / "python" / "compile").rglob("*.py")
    )

    only = set(args.only.split(",")) if args.only else None
    manifest = []
    n_lowered = 0
    t0 = time.time()
    for name, prog in sorted(model.REGISTRY.items()):
        if only and name not in only:
            continue
        hlo_path = out_dir / f"{name}.hlo.txt"
        stale = (
            args.force
            or not hlo_path.exists()
            or hlo_path.stat().st_mtime < src_mtime
        )
        if stale:
            text = lower_program(prog)
            hlo_path.write_text(text)
            n_lowered += 1
            print(f"  lowered {name:40s} {len(text) // 1024:6d} KiB",
                  file=sys.stderr)
        manifest.append({
            "name": name,
            "file": hlo_path.name,
            "inputs": [list(s) for s in prog.arg_shapes],
            "outputs": output_shapes(prog),
            "flops": prog.flops,
        })

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))

    if args.out:  # legacy single-file mode
        first = sorted(model.REGISTRY)[0]
        (pathlib.Path(args.out)).write_text(
            (out_dir / f"{first}.hlo.txt").read_text())

    print(f"aot: {len(manifest)} programs, {n_lowered} lowered "
          f"in {time.time() - t0:.1f}s -> {out_dir}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
