"""L2 branch programs: every REGISTRY entry vs its oracle + AOT checks.

Each program is evaluated on random inputs and compared against its
pure-jnp `ref_fn`; the AOT path is round-tripped (lower → HLO text) for
a representative subset and checked for the properties the Rust loader
relies on (no custom-calls, ENTRY present, tuple return).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model


RNG = np.random.default_rng(7)


def materialize(prog: model.Program):
    return [
        jnp.asarray(RNG.standard_normal(tuple(s)).astype(np.float32) * 0.1)
        for s in prog.arg_shapes
    ]


SMALL = [
    name
    for name, p in model.REGISTRY.items()
    if np.prod([np.prod(s) for s in p.arg_shapes]) < 5e12
]


@pytest.mark.parametrize("name", sorted(model.REGISTRY))
def test_program_matches_oracle(name):
    prog = model.REGISTRY[name]
    assert prog.ref_fn is not None, f"{name} has no oracle"
    args = materialize(prog)
    got = prog.fn(*args)
    want = prog.ref_fn(*args)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=5e-3, atol=5e-3
        )


@pytest.mark.parametrize("name", sorted(model.REGISTRY))
def test_program_flops_positive_and_shapes_consistent(name):
    prog = model.REGISTRY[name]
    assert prog.flops > 0
    outs = jax.eval_shape(prog.fn, *prog.example_args())
    assert len(outs) >= 1
    for o in outs:
        assert all(d > 0 for d in o.shape)


@pytest.mark.parametrize(
    "name", ["matmul_64x64x64", "layernorm_77x512", "ew_add_4096", "softmax_192x384"]
)
def test_aot_hlo_text_properties(name):
    prog = model.REGISTRY[name]
    text = aot.lower_program(prog)
    assert "ENTRY" in text, "HLO text must have an entry computation"
    assert "custom-call" not in text, "CPU PJRT cannot run custom-calls"
    # tuple return (the rust loader unpacks with to_tuple)
    assert "tuple" in text.lower()


def test_registry_names_are_stable_identifiers():
    for name in model.REGISTRY:
        assert " " not in name
        assert name == name.lower()


def test_registry_covers_zoo_hints():
    """Programs the Rust zoo hints at must exist in the registry."""
    needed = [
        "attn_77x512_h8",
        "ffn_77x512x2048",
        "layernorm_77x512",
        "attn_128x768_h12",
        "ffn_128x768x3072",
        "layernorm_128x768",
        "attn_192x384_h6",
        "ffn_192x384x1536",
        "layernorm_192x384",
        "conv3x3_silu_40x40x64x128_s2",
        "matmul_64x64x64",
    ]
    for name in needed:
        assert name in model.REGISTRY, f"zoo hint {name} missing"


def test_output_shapes_helper_matches_eval_shape():
    prog = model.REGISTRY["matmul_64x64x64"]
    assert aot.output_shapes(prog) == [[64, 64]]
