"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Parametrised shape sweeps + hypothesis-driven random shapes.  This is
the CORE numeric signal for the whole stack: the Rust engine executes
AOT artifacts lowered from these exact kernels, so agreement here means
agreement on the request path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import attention as attn_k
from compile.kernels import conv as conv_k
from compile.kernels import elementwise as ew_k
from compile.kernels import matmul as mm_k
from compile.kernels import norm as norm_k
from compile.kernels import ref


RNG = np.random.default_rng(0)


def arr(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def check(a, b, rtol=1e-4, atol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------- matmul

@pytest.mark.parametrize(
    "m,k,n",
    [(8, 8, 8), (64, 64, 64), (96, 80, 112), (128, 256, 64), (77, 512, 512), (1, 384, 51)],
)
def test_matmul_shapes(m, k, n):
    x, y = arr(m, k), arr(k, n)
    check(mm_k.matmul(x, y), ref.matmul(x, y), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (128, 128, 128), (16, 64, 8)])
def test_matmul_block_shapes_equivalent(bm, bn, bk):
    """Block-shape choice must never change the numerics."""
    x, y = arr(96, 64), arr(64, 80)
    base = ref.matmul(x, y)
    check(mm_k.matmul(x, y, bm=bm, bn=bn, bk=bk), base, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("act", ["none", "relu", "gelu", "silu"])
def test_matmul_bias_act(act):
    x, w, b = arr(64, 96), arr(96, 48), arr(48)
    check(
        mm_k.matmul_bias_act(x, w, b, act=act),
        ref.bias_act(ref.matmul(x, w), b, act),
        rtol=1e-3,
        atol=1e-3,
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
)
def test_matmul_hypothesis(m, k, n):
    rng = np.random.default_rng(m * 10007 + k * 101 + n)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    check(mm_k.matmul(x, y), ref.matmul(x, y), rtol=2e-3, atol=2e-3)


def test_matmul_rejects_mismatch():
    with pytest.raises(AssertionError):
        mm_k.matmul(arr(4, 5), arr(6, 4))


def test_vmem_and_mxu_estimators():
    assert mm_k.vmem_bytes(128, 128, 128) == 4 * 3 * 128 * 128
    assert mm_k.mxu_utilization(128, 128, 128) == 1.0
    assert mm_k.mxu_utilization(64, 128, 128) == 0.5
    assert mm_k.mxu_utilization(130, 128, 128) < 0.6


# ----------------------------------------------------------- norm kernels

@pytest.mark.parametrize("rows,d", [(4, 16), (77, 512), (128, 768), (192, 384), (1, 64)])
def test_layernorm(rows, d):
    x, g, b = arr(rows, d), arr(d), arr(d)
    check(norm_k.layernorm(x, g, b), ref.layernorm(x, g, b))


@pytest.mark.parametrize("rows,d", [(4, 16), (128, 128), (192, 384), (3, 1000)])
def test_softmax(rows, d):
    x = arr(rows, d)
    out = norm_k.softmax(x)
    check(out, ref.softmax(x), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out).sum(-1), 1.0, rtol=1e-5)


def test_softmax_extreme_values_stable():
    x = jnp.asarray([[1e4, -1e4, 0.0, 5.0]], dtype=jnp.float32)
    out = np.asarray(norm_k.softmax(x))
    assert np.isfinite(out).all()
    assert abs(out.sum() - 1.0) < 1e-5


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 64), d=st.integers(2, 256))
def test_layernorm_hypothesis(rows, d):
    rng = np.random.default_rng(rows * 997 + d)
    x = jnp.asarray(rng.standard_normal((rows, d)).astype(np.float32))
    g = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    check(norm_k.layernorm(x, g, b), ref.layernorm(x, g, b), rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------- attention

@pytest.mark.parametrize("t,s,d", [(16, 16, 8), (77, 77, 64), (64, 192, 32), (1, 7, 16)])
def test_attention(t, s, d):
    q, k, v = arr(t, d), arr(s, d), arr(s, d)
    check(attn_k.attention(q, k, v), ref.attention(q, k, v), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("t,d,h", [(16, 32, 4), (77, 512, 8), (64, 96, 3)])
def test_mha(t, d, h):
    # scale weights ~1/sqrt(d) so attention scores stay in the
    # well-conditioned softmax regime (as trained weights would)
    x = arr(t, d)
    ws = [arr(d, d) / np.sqrt(d) for _ in range(4)]
    check(
        attn_k.mha(x, *ws, num_heads=h),
        ref.mha(x, *ws, h),
        rtol=2e-3,
        atol=2e-3,
    )


# ----------------------------------------------------------- elementwise

@pytest.mark.parametrize("op", ["add", "sub", "mul", "max"])
@pytest.mark.parametrize("shape", [(64,), (17, 9), (2, 3, 5)])
def test_binary(op, shape):
    x, y = arr(*shape), arr(*shape)
    check(ew_k.binary(x, y, op=op), ref.elementwise(x, y, op), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("op", ["relu", "silu", "gelu"])
def test_unary(op):
    x = arr(33, 41)
    expect = {"relu": ref.relu, "silu": ref.silu}.get(op)
    if expect is None:
        import jax
        expect = jax.nn.gelu
    check(ew_k.unary(x, op=op), expect(x), rtol=1e-5, atol=1e-5)


def test_binary_rejects_unknown_op():
    with pytest.raises(Exception):
        ew_k.binary(arr(4), arr(4), op="pow")


# ----------------------------------------------------------- convolution

@pytest.mark.parametrize(
    "shape,k,cout,stride",
    [
        ((1, 8, 8, 3), 3, 8, 1),
        ((2, 16, 16, 8), 3, 12, 1),
        ((1, 16, 16, 8), 3, 16, 2),
        ((1, 7, 9, 4), 3, 6, 2),
        ((1, 12, 12, 6), 5, 4, 1),
        ((1, 10, 10, 3), 1, 7, 1),
    ],
)
def test_conv2d(shape, k, cout, stride):
    x = arr(*shape)
    w = arr(k, k, shape[-1], cout)
    check(
        conv_k.conv2d(x, w, stride=stride),
        ref.conv2d(x, w, stride=stride),
        rtol=2e-3,
        atol=2e-3,
    )


@pytest.mark.parametrize(
    "shape,k,stride",
    [((2, 16, 16, 8), 3, 1), ((1, 7, 9, 4), 3, 2), ((1, 12, 12, 6), 5, 1)],
)
def test_dwconv2d(shape, k, stride):
    x = arr(*shape)
    w = arr(k, k, shape[-1], 1)
    check(
        conv_k.dwconv2d(x, w, stride=stride),
        ref.dwconv2d(x, w, stride=stride),
        rtol=2e-3,
        atol=2e-3,
    )


@pytest.mark.parametrize("mode", ["max", "avg"])
def test_pool2d(mode, shape=(2, 16, 16, 8)):
    x = arr(*shape)
    if mode == "max":
        check(conv_k.maxpool2d(x), ref.maxpool2d(x), rtol=1e-6, atol=0)
    else:
        check(conv_k.avgpool2d(x), ref.avgpool2d(x), rtol=1e-5, atol=1e-5)


def test_im2col_matches_patch_extraction():
    x = arr(1, 6, 6, 2)
    cols = ref.im2col(x, 3, 3)
    assert cols.shape == (1, 6, 6, 18)
