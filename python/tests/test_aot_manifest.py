"""AOT manifest round-trip: `python -m compile.aot` output is exactly
what the Rust `runtime::Manifest` loader expects."""

import json
import pathlib
import subprocess
import sys

import pytest

from compile import model

REPO = pathlib.Path(__file__).resolve().parents[2]
ARTIFACTS = REPO / "artifacts"


@pytest.fixture(scope="module")
def manifest():
    path = ARTIFACTS / "manifest.json"
    if not path.exists():
        pytest.skip("artifacts not built (run `make artifacts`)")
    return json.loads(path.read_text())


def test_manifest_covers_registry(manifest):
    names = {m["name"] for m in manifest}
    assert names == set(model.REGISTRY), "manifest out of sync with REGISTRY"


def test_manifest_entries_well_formed(manifest):
    for m in manifest:
        assert set(m) >= {"name", "file", "inputs", "outputs", "flops"}
        assert m["file"].endswith(".hlo.txt")
        assert (ARTIFACTS / m["file"]).exists(), f"{m['file']} missing"
        assert all(isinstance(s, list) for s in m["inputs"])
        assert len(m["outputs"]) >= 1
        assert m["flops"] > 0


def test_manifest_shapes_match_registry(manifest):
    for m in manifest:
        prog = model.REGISTRY[m["name"]]
        assert [list(s) for s in prog.arg_shapes] == m["inputs"]


def test_hlo_files_parse_as_text(manifest):
    for m in manifest[:5]:
        text = (ARTIFACTS / m["file"]).read_text()
        assert text.startswith("HloModule"), f"{m['file']} not HLO text"
        assert "custom-call" not in text


def test_incremental_aot_is_noop():
    """Re-running aot on an up-to-date tree lowers nothing."""
    if not (ARTIFACTS / "manifest.json").exists():
        pytest.skip("artifacts not built")
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot"],
        cwd=REPO / "python",
        capture_output=True,
        text=True,
        check=True,
    )
    assert ", 0 lowered" in out.stderr, out.stderr
